"""Training driver: data pipeline -> jit'd train step -> logging/ckpt."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, MarkovTextDataset
from repro.models import build_model
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamWConfig, init_opt_state


@dataclass
class TrainResult:
    losses: List[float] = field(default_factory=list)
    grad_norms: List[float] = field(default_factory=list)
    tokens_per_s: float = 0.0

    @property
    def final_loss(self) -> float:
        return float(jnp.mean(jnp.asarray(self.losses[-10:])))


def train(cfg: ModelConfig, *, steps: int = 100, batch_size: int = 8,
          seq_len: int = 128, lr: float = 3e-4, seed: int = 0,
          ckpt_path: Optional[str] = None, log_every: int = 10,
          dtype=jnp.float32, accum_steps: int = 1,
          log_fn: Callable[[str], None] = print) -> TrainResult:
    from repro.launch.steps import make_train_step  # avoid import cycle
    model = build_model(cfg, dtype=dtype)
    params = model.init(jax.random.PRNGKey(seed))
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                          total_steps=steps)
    opt_state = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      accum_steps=accum_steps))
    data = MarkovTextDataset(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, batch_size=batch_size,
        seed=seed))
    res = TrainResult()
    it = iter(data)
    t0 = time.perf_counter()
    for step in range(steps):
        batch = jnp.asarray(next(it))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        res.losses.append(loss)
        res.grad_norms.append(float(metrics["grad_norm"]))
        if log_every and step % log_every == 0:
            log_fn(f"step {step:5d} loss {loss:.4f} "
                   f"gnorm {res.grad_norms[-1]:.3f}")
    res.tokens_per_s = steps * batch_size * seq_len / (
        time.perf_counter() - t0)
    if ckpt_path:
        save_checkpoint(ckpt_path, {"params": params}, step=steps)
    log_fn(f"done: final loss {res.final_loss:.4f} "
           f"({res.tokens_per_s:.0f} tok/s); "
           f"data entropy floor {data.optimal_nll():.4f}")
    return res
