"""AdamW optimizer (pure-JAX pytree implementation) with cosine schedule.

States are kept in the same dtype as parameters by default; ``state_dtype``
can lower them (bf16) for the memory-tight dry-run configurations.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = None          # None -> same as param


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    def zeros_like(p):
        dt = cfg.state_dtype or p.dtype
        return jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros_like, params),
                    nu=jax.tree.map(zeros_like, params))


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig
                  ) -> Tuple[Any, OptState, jax.Array]:
    """One AdamW step; returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, mu=new_m, nu=new_v), gnorm
