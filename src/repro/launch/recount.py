import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Re-derive the count-based roofline fields of an existing dry-run JSON
(collective bytes / flops / dominant term) using only the cheap reduced-
depth probes — the expensive memory-program compiles are not repeated.

Usage: python -m repro.launch.recount dryrun_1pod.json [--multi-pod]
"""

import argparse
import json
import traceback


def recount_one(rec, multi_pod: bool, build_overrides=None):
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config, get_shape
    from repro.launch import steps
    from repro.launch.dryrun import _counts_from_compiled, _extrapolate
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis as roofline
    from repro.sharding.partition import batch_pspec

    shape = get_shape(rec["shape"])
    cfg = steps.adapt_for_shape(get_config(rec["arch"]), shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    build_overrides = build_overrides or {}

    plen = steps.pattern_len(cfg)
    units_full = cfg.num_layers / plen
    probes = []
    for units in (1, 2):
        pcfg = steps.probe_config(cfg, units)
        pb = steps.build(pcfg, shape, mesh, scan_layers=False,
                         accum_steps=1, ce_chunk=shape.seq_len,
                         **build_overrides)
        probes.append(_counts_from_compiled(pb.lower().compile()))
    counts = _extrapolate(probes[0], probes[1], units_full)

    bspec = batch_pspec(shape.global_batch, mesh)
    dp = 1
    if bspec != P(None):
        entry = bspec[0]
        for a in ((entry,) if isinstance(entry, str) else (entry or ())):
            dp *= mesh.shape[a]
    corr = roofline.scan_corrections(cfg, shape, dp, shape.mode)
    flops = counts["flops"] + corr["flops"]
    hbm_bytes = counts["bytes"] + corr["bytes"]
    coll_bytes = sum(counts["collective_bytes"].values())
    compute_s = flops / roofline.PEAK_FLOPS
    memory_s = hbm_bytes / roofline.HBM_BW
    coll_s = coll_bytes / roofline.LINK_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    mflops = roofline.model_flops(cfg, shape)
    rec.update({
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collective_bytes_per_device": coll_bytes,
        "collective_counts": counts["collective_counts"],
        "collective_bytes_by_kind": counts["collective_bytes"],
        "scan_correction_flops": corr["flops"],
        "compute_ms": round(compute_s * 1e3, 3),
        "memory_ms": round(memory_s * 1e3, 3),
        "collective_ms": round(coll_s * 1e3, 3),
        "dominant": dominant,
        "useful_flops_ratio": round(mflops / max(flops * chips, 1.0), 4),
    })
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("json_path")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    recs = json.load(open(args.json_path))
    for i, rec in enumerate(recs):
        if not rec.get("ok"):
            continue
        try:
            recs[i] = recount_one(rec, args.multi_pod)
            print(f"[recount] {rec['arch']} x {rec['shape']}: "
                  f"coll={rec['collective_ms']}ms dom={rec['dominant']}",
                  flush=True)
        except Exception:
            traceback.print_exc()
        json.dump(recs, open(args.json_path, "w"), indent=1)


if __name__ == "__main__":
    main()
