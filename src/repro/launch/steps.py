"""Step builders + abstract input specs for every (arch × input shape).

This is the single source used by the dry-run, the examples and the
benchmarks:

  * ``build(arch_cfg, shape, mesh)``   -> StepBundle with jit-able step fn,
    abstract inputs (ShapeDtypeStruct, no allocation) and shardings.
  * train shapes lower ``train_step``  (loss + grads + AdamW update)
  * prefill shapes lower ``prefill_step``
  * decode shapes lower ``serve_step`` (ONE new token against a KV cache of
    seq_len capacity — ring-buffer capped for sliding/local attention)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model, frontend_shape
from repro.models.transformer import ExecutionContext, Model
from repro.sharding.partition import (batch_pspec, cache_pspecs,
                                      params_pspecs)
from repro.training.optimizer import AdamWConfig, OptState, apply_updates, \
    init_opt_state


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def experts_padded(cfg: ModelConfig, mesh: Optional[Mesh],
                   model_axis: str = "model") -> int:
    if cfg.moe is None:
        return 0
    if mesh is None:
        return cfg.moe.num_experts
    return round_up(cfg.moe.num_experts, mesh.shape[model_axis])


LONG_CONTEXT_WINDOW = 8192


def adapt_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """long_500k decode requires sub-quadratic attention: SSM/hybrid/sliding
    archs run natively; full-attention archs switch to the sliding-window
    decode variant (ring KV cache, window 8192) — see DESIGN.md §4."""
    if (shape.name == "long_500k" and shape.mode == "decode"
            and cfg.uses_attention and not cfg.subquadratic):
        return dataclasses.replace(cfg, attention="sliding",
                                   sliding_window=LONG_CONTEXT_WINDOW,
                                   mla_kv_lora_rank=0)
    return cfg


def pattern_len(cfg: ModelConfig) -> int:
    from repro.models.transformer import pattern_group
    try:
        return len(pattern_group(cfg))
    except Exception:
        return 1


def probe_config(cfg: ModelConfig, units: int) -> ModelConfig:
    """Reduced-depth variant for count-accurate probing: ``units`` repeats
    of the layer pattern, encoder scaled proportionally. All other dims are
    the full config's — counts are linear in units, so two probes determine
    the exact (intercept, slope) for the full depth."""
    plen = pattern_len(cfg)
    kw = dict(num_layers=units * plen)
    if cfg.is_encoder_decoder and cfg.num_encoder_layers:
        ratio = cfg.num_encoder_layers / cfg.num_layers
        kw["num_encoder_layers"] = max(1, round(units * plen * ratio))
    return dataclasses.replace(cfg, **kw)


def default_accum_steps(cfg: ModelConfig, shape: ShapeConfig,
                        mesh: Optional[Mesh],
                        target_tokens_per_device: int = 16384) -> int:
    """Micro-batch count bounding per-device activation tokens."""
    if shape.mode != "train" or mesh is None:
        return 1
    from repro.sharding.partition import batch_pspec
    spec = batch_pspec(shape.global_batch, mesh)
    dp = 1
    if spec != P(None):
        entry = spec[0]
        axes = (entry,) if isinstance(entry, str) else (entry or ())
        for a in axes:
            dp *= mesh.shape[a]
    tokens_per_dev = shape.global_batch // dp * shape.seq_len
    want = max(1, tokens_per_dev // target_tokens_per_device)
    # largest accum <= want that divides the per-device batch
    b_dev = shape.global_batch // dp
    accum = 1
    for k in range(1, b_dev + 1):
        if b_dev % k == 0 and k <= want:
            accum = k
    return accum


@dataclass
class StepBundle:
    name: str
    step_fn: Callable                  # (params[, opt_state], **inputs)
    abstract_args: Tuple               # ShapeDtypeStructs, jit-order args
    in_shardings: Tuple
    out_shardings: Any
    model: Model
    donate_argnums: Tuple[int, ...] = ()
    accum_steps: int = 1

    def lower(self):
        return jax.jit(self.step_fn,
                       in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums,
                       ).lower(*self.abstract_args)


def _sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec or P()))


def _with_sharding(tree, spec_tree, mesh):
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)),
        tree, spec_tree)


def cluster_from_mesh(mesh: Mesh, dtype_bytes: int = 2,
                      model_axis: str = "model"):
    """Map a launch mesh onto the planner's DEP cluster view: the expert
    group is the ``model`` axis (experts are expert-parallel over it, see
    repro.core.dep) and the attention group is the data-parallel extent."""
    from repro.configs.base import DepClusterConfig
    shape = dict(mesh.shape)
    n = mesh.size
    if n < 2:
        raise ValueError("DEP needs >= 2 devices (an attention group AND "
                         f"an expert group); mesh has {n}")
    # a mesh whose model axis spans every device leaves no room for the
    # attention group under the cluster's disjoint-groups accounting
    eg = min(shape.get(model_axis, 1), n - 1)
    ag = max(min(n // eg, n - eg), 1)
    return DepClusterConfig(num_devices=n, ag=ag, eg=eg,
                            dtype_bytes=dtype_bytes)


def launch_policy(cfg: ModelConfig, mesh: Mesh, policy: str = "findep",
                  profile=None, mem_cap_samples: int = 64,
                  static_seq_len: Optional[int] = None,
                  profile_store=None):
    """Build a ``repro.sched`` SchedulePolicy for a static launch pipeline
    by name, so dry-runs and step builders can plan per shape instead of
    demanding an explicit frozen plan (ROADMAP follow-up).

    ``profile`` is a HardwareProfile, a registry name, or a name stored in
    ``profile_store`` (a repro.profiling.ProfileStore or its root path) —
    i.e. a calibrated fit from ``examples/serve_moe.py --calibrate``.
    Defaults to the TPU v5e analytic profile, the launch target."""
    from repro.core.perf_model import (HardwareProfile, TPU_V5E, get_profile)
    from repro.core.planner import FinDEPPlanner, PlannerConfig
    from repro.sched import make_policy
    if isinstance(profile, HardwareProfile):
        hw = profile
    elif profile is None:
        hw = TPU_V5E
    else:
        hw = None
        if profile_store is not None:
            from repro.profiling import ProfileStore
            store = (profile_store
                     if isinstance(profile_store, ProfileStore)
                     else ProfileStore(profile_store))
            try:
                hw = store.load_profile(profile)
            except KeyError:
                hw = None
        if hw is None:
            hw = get_profile(profile)
    planner = FinDEPPlanner(cfg, cluster_from_mesh(mesh), hw,
                            PlannerConfig(mem_cap_samples=mem_cap_samples))
    return make_policy(policy, planner, static_seq_len=static_seq_len)


def resolve_launch_plan(cfg: ModelConfig, mesh: Optional[Mesh],
                        policy, seq_len: int, mode: str = "prefill",
                        batch_per_device: Optional[int] = None,
                        profile=None, profile_store=None):
    """Resolve the schedule a static pipeline should compile for one
    shape. ``policy`` is a SchedulePolicy or a name ("findep" etc.);
    returns None when the config/mesh cannot be DEP-scheduled."""
    if not cfg.is_moe or mesh is None:
        return None
    if isinstance(policy, str):
        policy = launch_policy(cfg, mesh, policy, profile=profile,
                               static_seq_len=seq_len,
                               profile_store=profile_store)
    phase = "decode" if mode == "decode" else "prefill"
    return policy.resolve(phase, seq_len, batch_per_device)


def make_model(cfg: ModelConfig, mesh: Optional[Mesh] = None,
               plan=None, scan_layers: Optional[bool] = None,
               moe_impl: Optional[str] = None, remat: bool = False,
               policy=None, seq_len: Optional[int] = None,
               batch_per_device: Optional[int] = None, profile=None,
               interleave: str = "streams",
               dtype=jnp.bfloat16) -> Model:
    if scan_layers is None:
        scan_layers = cfg.num_layers > 8
    if moe_impl is None:
        moe_impl = "dep" if (mesh is not None and cfg.is_moe) else "capacity"
    data_axes = (tuple(a for a in mesh.axis_names if a != "model")
                 if mesh is not None else ("data",))
    ctx = ExecutionContext(mesh=mesh, moe_impl=moe_impl,
                           remat=remat, data_axes=data_axes)
    if plan is None and policy is not None:
        if seq_len is None:
            raise ValueError("make_model(policy=...) needs seq_len — the "
                             "shape the compiled schedule is for")
        plan = resolve_launch_plan(cfg, mesh, policy, seq_len,
                                   batch_per_device=batch_per_device,
                                   profile=profile)
    # static pipelines compile one schedule per shape: the resolved plan is
    # lowered here to the ExecProgram the DEP walker consumes, so the
    # emission policy (r1-stream interleaving + priority hints) is fixed at
    # build time alongside the schedule itself
    if plan is not None and hasattr(plan, "exec_program"):
        plan = plan.exec_program(interleave=interleave)
    return build_model(cfg, ctx=ctx,
                       num_experts_padded=experts_padded(cfg, mesh),
                       scan_layers=scan_layers, dtype=dtype, plan=plan)


def abstract_params(model: Model, dtype=jnp.bfloat16):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype
                                       if s.dtype == jnp.float32 else s.dtype),
        shapes)


# ---------------------------------------------------------------------------
# input specs per mode
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                mesh: Optional[Mesh] = None, dtype=jnp.bfloat16
                ) -> Dict[str, Any]:
    """Abstract model inputs (ShapeDtypeStruct) for one input shape."""
    B, S = shape.global_batch, shape.seq_len
    bspec = batch_pspec(B, mesh) if mesh is not None else None

    def sds(shp, dt, spec):
        return _sds(shp, dt, mesh, spec)

    specs: Dict[str, Any] = {}
    if shape.mode in ("train", "prefill"):
        specs["tokens"] = sds((B, S), jnp.int32,
                              P(*(bspec or P(None)), None) if mesh else None)
    else:
        specs["tokens"] = sds((B, 1), jnp.int32,
                              P(*(bspec or P(None)), None) if mesh else None)
    fs = frontend_shape(cfg, shape)
    if fs is not None:
        if cfg.is_encoder_decoder and shape.mode == "decode":
            # decode consumes precomputed encoder memory
            specs["memory"] = sds((B, fs[1], cfg.d_model), dtype,
                                  P(*(bspec or P(None)), None, None)
                                  if mesh else None)
        else:
            specs["extra"] = sds(fs, dtype,
                                 P(*(bspec or P(None)), None, None)
                                 if mesh else None)
    return specs


def decode_cache_specs(model: Model, cfg: ModelConfig, shape: ShapeConfig,
                       mesh: Optional[Mesh], dtype=jnp.bfloat16):
    B, S = shape.global_batch, shape.seq_len
    budget = S
    if cfg.family == "vlm":
        budget += cfg.frontend_tokens
    cache_shapes = jax.eval_shape(
        partial(model.init_cache, B, budget, dtype))
    if mesh is None:
        return cache_shapes
    pspecs = cache_pspecs(cache_shapes, cfg, mesh, B,
                          stacked=model.scan_layers)
    return _with_sharding(cache_shapes, pspecs, mesh)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(model: Model, opt_cfg: Optional[AdamWConfig] = None,
                    accum_steps: int = 1, accum_dtype=jnp.float32,
                    ce_chunk: Optional[int] = None):
    """Gradient-accumulating train step: the global batch is split into
    ``accum_steps`` micro-batches scanned with value_and_grad inside the
    body, bounding peak activation memory to one micro-batch."""
    opt_cfg = opt_cfg or AdamWConfig(state_dtype=jnp.bfloat16)

    def train_step(params, opt_state, tokens, extra=None):
        def loss_fn(p, tok, ex):
            if ce_chunk is not None:
                return model.loss(p, tok, extra_embeds=ex,
                                  ce_chunk=ce_chunk)
            return model.loss(p, tok, extra_embeds=ex)

        if accum_steps <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens, extra)
        else:
            B = tokens.shape[0]
            mb = B // accum_steps
            tok_mb = tokens.reshape(accum_steps, mb, *tokens.shape[1:])
            ex_mb = (extra.reshape(accum_steps, mb, *extra.shape[1:])
                     if extra is not None else None)

            def body(carry, inp):
                g_acc, loss_acc = carry
                tok_i = inp[0]
                ex_i = inp[1] if extra is not None else None
                loss_i, g_i = jax.value_and_grad(loss_fn)(params, tok_i, ex_i)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dtype), g_acc, g_i)
                return (g_acc, loss_acc + loss_i), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            xs = (tok_mb, ex_mb) if extra is not None else (tok_mb,)
            (g_sum, loss_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), xs)
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            loss = loss_sum / accum_steps
        new_p, new_s, gnorm = apply_updates(params, grads, opt_state,
                                            opt_cfg)
        return new_p, new_s, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, tokens, extra=None):
        memory = None
        if model.cfg.is_encoder_decoder and extra is not None:
            memory = model.encode(params, extra)
            extra = None
        logits, caches = model.prefill(params, tokens, extra_embeds=extra,
                                       memory=memory)
        return logits, caches
    return prefill_step


def make_serve_step(model: Model):
    """ONE decode step: next-token logits + greedy token, cache update."""
    def serve_step(params, tokens, caches, memory=None):
        logits, caches = model.decode_step(params, tokens, caches,
                                           memory=memory)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], caches
    return serve_step


# ---------------------------------------------------------------------------
# the bundle
# ---------------------------------------------------------------------------

def build(cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh] = None,
          plan=None, dtype=jnp.bfloat16, opt_cfg: Optional[AdamWConfig] = None,
          scan_layers: Optional[bool] = None,
          moe_impl: Optional[str] = None,
          remat: Optional[bool] = None,
          accum_steps: Optional[int] = None,
          attn_impl: Optional[str] = None,
          ce_chunk: Optional[int] = None,
          policy=None, profile=None, profile_store=None,
          interleave: str = "streams") -> StepBundle:
    if remat is None:
        remat = shape.mode == "train"
    if accum_steps is None:
        accum_steps = default_accum_steps(cfg, shape, mesh)
    if plan is None and policy is not None and mesh is not None:
        # per-shape schedule by policy name: the solver sees this shape's
        # per-device arrived batch (falls back to throughput mode when the
        # batch admits no feasible decomposition)
        from repro.sharding.partition import batch_pspec
        spec = batch_pspec(shape.global_batch, mesh)
        dp = 1
        if spec != P(None):
            entry = spec[0]
            axes = (entry,) if isinstance(entry, str) else (entry or ())
            for a in axes:
                dp *= mesh.shape[a]
        plan = resolve_launch_plan(cfg, mesh, policy, shape.seq_len,
                                   mode=shape.mode,
                                   batch_per_device=shape.global_batch // dp,
                                   profile=profile,
                                   profile_store=profile_store)
    model = make_model(cfg, mesh, plan=plan, scan_layers=scan_layers,
                       moe_impl=moe_impl, remat=remat,
                       interleave=interleave, dtype=dtype)
    if attn_impl is not None:
        model.ctx.attn_impl = attn_impl
    params_abs = abstract_params(model, dtype)
    # FSDP policy: train shards aggressively (opt states dominate);
    # inference keeps weights resident unless truly huge (re-gathering
    # weights every decode step wastes ICI).
    fsdp_threshold = (8 * 1024 * 1024 if shape.mode == "train"
                      else 64 * 1024 * 1024)
    pspecs = params_pspecs(params_abs, cfg, mesh=mesh,
                           fsdp_threshold_elems=fsdp_threshold)
    if mesh is not None:
        params_abs = _with_sharding(params_abs, pspecs, mesh)
        params_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    else:
        params_sh = None
    inputs = input_specs(cfg, shape, mesh, dtype)

    if shape.mode == "train":
        opt_cfg = opt_cfg or AdamWConfig(state_dtype=jnp.bfloat16)
        opt_abs = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg),
                                 params_abs)
        if mesh is not None:
            opt_pspecs = OptState(step=P(),
                                  mu=pspecs, nu=pspecs)
            opt_abs = _with_sharding(opt_abs, opt_pspecs, mesh)
            opt_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                  opt_pspecs)
        else:
            opt_sh = None
        fn = make_train_step(model, opt_cfg, accum_steps=accum_steps,
                             ce_chunk=ce_chunk)
        args = [params_abs, opt_abs, inputs["tokens"]]
        shardings = [params_sh, opt_sh,
                     inputs["tokens"].sharding if mesh else None]
        if "extra" in inputs:
            args.append(inputs["extra"])
            shardings.append(inputs["extra"].sharding if mesh else None)
        out_sh = (params_sh, opt_sh, None) if mesh else None
        return StepBundle(
            name=f"{cfg.name}:{shape.name}:train",
            step_fn=fn, abstract_args=tuple(args),
            in_shardings=tuple(shardings) if mesh else None,
            out_shardings=out_sh,
            model=model, donate_argnums=(0, 1), accum_steps=accum_steps)

    if shape.mode == "prefill":
        fn = make_prefill_step(model)
        args = [params_abs, inputs["tokens"]]
        shardings = [params_sh, inputs["tokens"].sharding if mesh else None]
        if "extra" in inputs:
            args.append(inputs["extra"])
            shardings.append(inputs["extra"].sharding if mesh else None)
        return StepBundle(
            name=f"{cfg.name}:{shape.name}:prefill",
            step_fn=fn, abstract_args=tuple(args),
            in_shardings=tuple(shardings) if mesh else None,
            out_shardings=None, model=model)

    # decode
    caches_abs = decode_cache_specs(model, cfg, shape, mesh, dtype)
    fn = make_serve_step(model)
    args = [params_abs, inputs["tokens"], caches_abs]
    shardings = [params_sh,
                 inputs["tokens"].sharding if mesh else None,
                 jax.tree.map(lambda s: s.sharding, caches_abs)
                 if mesh else None]
    if "memory" in inputs:
        args.append(inputs["memory"])
        shardings.append(inputs["memory"].sharding if mesh else None)
    cache_out_sh = (jax.tree.map(lambda s: s.sharding, caches_abs)
                    if mesh else None)
    return StepBundle(
        name=f"{cfg.name}:{shape.name}:decode",
        step_fn=fn, abstract_args=tuple(args),
        in_shardings=tuple(shardings) if mesh else None,
        out_shardings=(None, cache_out_sh) if mesh else None,
        model=model, donate_argnums=(2,))
