"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import
and only then calls make_production_mesh().
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1, data: int = 0):
    """Small mesh over however many host devices exist (tests/examples)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    data = data or n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
