import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import (jax locks the device
# count on first init). Everything below is ordinary code.

"""Multi-pod dry-run driver.

For every (architecture x input shape) the step program is lowered and
compiled against the production mesh — 16x16 ("data","model") single-pod
and 2x16x16 ("pod","data","model") multi-pod — using ShapeDtypeStructs
only (no real allocation). Failures here are sharding bugs.

Accounting (see EXPERIMENTS.md §Dry-run for the rationale):
  * MEMORY program: the deployable step (scanned layers, chunked
    attention, gradient accumulation) -> memory_analysis().
  * COUNT probes: XLA's cost_analysis counts a While body once, so FLOPs /
    collective bytes come from two reduced-depth UNROLLED probes (1x and
    2x the layer pattern) extrapolated linearly — exact for homogeneous
    stacks — plus analytic corrections for scans inside layers
    (chunked-attention q/k chunk grid, xLSTM time recurrence).

Usage:
  python -m repro.launch.dryrun --arch qwen2-moe-a2.7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""

import argparse
import json
import sys
import time
import traceback


def _counts_from_compiled(compiled):
    from repro.roofline import analysis as roofline
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    stats = roofline.parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": dict(stats.wire_bytes),
        "collective_counts": dict(stats.counts),
    }


def _extrapolate(c1, c2, units: float):
    """val(u) = v1 + (v2 - v1) * (u - 1); exact for homogeneous stacks."""
    def lin(a, b):
        return a + (b - a) * (units - 1.0)
    out = {
        "flops": lin(c1["flops"], c2["flops"]),
        "bytes": lin(c1["bytes"], c2["bytes"]),
        "collective_bytes": {}, "collective_counts": {},
    }
    for key in ("collective_bytes", "collective_counts"):
        kinds = set(c1[key]) | set(c2[key])
        for k in kinds:
            out[key][k] = lin(c1[key].get(k, 0.0), c2[key].get(k, 0.0))
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            verbose: bool = True, plan=None, counts_probes: bool = True,
            policy=None, profile=None, profile_store=None,
            build_overrides=None):
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config, get_shape
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis as roofline
    from repro.sharding.partition import batch_pspec

    shape = get_shape(shape_name)
    cfg = steps.adapt_for_shape(get_config(arch), shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    build_overrides = dict(build_overrides or {})
    # a SchedulePolicy name plans the shape instead of an explicit frozen
    # plan (profile = hardware fit to plan against; default TPU v5e)
    if policy is not None:
        build_overrides.setdefault("policy", policy)
        build_overrides.setdefault("profile", profile)
        build_overrides.setdefault("profile_store", profile_store)

    # ---- memory-accurate program (the deployable step) -------------------
    t0 = time.perf_counter()
    bundle = steps.build(cfg, shape, mesh, plan=plan, **build_overrides)
    lowered = bundle.lower()
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    mem = compiled.memory_analysis()

    # ---- count-accurate probes -------------------------------------------
    plen = steps.pattern_len(cfg)
    units_full = cfg.num_layers / plen
    if counts_probes and units_full >= 2:
        probes = []
        for units in (1, 2):
            pcfg = steps.probe_config(cfg, units)
            pb = steps.build(pcfg, shape, mesh, plan=plan,
                             scan_layers=False, accum_steps=1,
                             ce_chunk=shape.seq_len, **build_overrides)
            probes.append(_counts_from_compiled(pb.lower().compile()))
        counts = _extrapolate(probes[0], probes[1], units_full)
    else:
        counts = _counts_from_compiled(compiled)

    bspec = batch_pspec(shape.global_batch, mesh)
    dp = 1
    if bspec != P(None):
        entry = bspec[0]
        axes = (entry,) if isinstance(entry, str) else (entry or ())
        for a in axes:
            dp *= mesh.shape[a]
    corr = roofline.scan_corrections(cfg, shape, dp, shape.mode)
    flops = counts["flops"] + corr["flops"]
    hbm_bytes = counts["bytes"] + corr["bytes"]
    coll_bytes = sum(counts["collective_bytes"].values())

    compute_s = flops / roofline.PEAK_FLOPS
    memory_s = hbm_bytes / roofline.HBM_BW
    coll_s = coll_bytes / roofline.LINK_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    mflops = roofline.model_flops(cfg, shape)
    useful = mflops / max(flops * chips, 1.0)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "accum_steps": bundle.accum_steps,
        "arg_bytes_per_device": mem.argument_size_in_bytes,
        "temp_bytes_per_device": mem.temp_size_in_bytes,
        "output_bytes_per_device": mem.output_size_in_bytes,
        "peak_gb_per_device": round(
            (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 1e9, 3),
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collective_bytes_per_device": coll_bytes,
        "collective_counts": counts["collective_counts"],
        "collective_bytes_by_kind": counts["collective_bytes"],
        "scan_correction_flops": corr["flops"],
        "compute_ms": round(compute_s * 1e3, 3),
        "memory_ms": round(memory_s * 1e3, 3),
        "collective_ms": round(coll_s * 1e3, 3),
        "dominant": dominant,
        "model_flops": mflops,
        "useful_flops_ratio": round(useful, 4),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s, "
              f"accum={bundle.accum_steps})")
        print(f"  memory/device: args={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.2f}GB "
              f"peak={rec['peak_gb_per_device']:.2f}GB")
        print(f"  roofline: compute={rec['compute_ms']}ms "
              f"memory={rec['memory_ms']}ms "
              f"collective={rec['collective_ms']}ms "
              f"dominant={dominant} useful={useful:.3f}")
        print(f"  collectives: { {k: int(v) for k, v in rec['collective_counts'].items()} }")
    return rec


def grid(multi_pod: bool, archs=None, shapes=None, json_path=None,
         stop_on_fail: bool = False, counts_probes: bool = True):
    from repro.configs import ASSIGNED_ARCHS, SHAPES
    archs = archs or list(ASSIGNED_ARCHS)
    shapes = shapes or list(SHAPES)
    results = []
    for arch in archs:
        for shape in shapes:
            try:
                results.append(run_one(arch, shape, multi_pod,
                                       counts_probes=counts_probes))
            except Exception as e:
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if multi_pod else "16x16",
                                "ok": False,
                                "error": f"{type(e).__name__}: {e}"})
                if stop_on_fail:
                    break
            if json_path:
                with open(json_path, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"[dryrun] {n_ok}/{len(results)} combinations compiled")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip count probes (memory program only)")
    ap.add_argument("--policy", default=None,
                    help="schedule by policy name (findep | static | "
                         "sequential | eps) instead of an explicit plan")
    ap.add_argument("--profile", default=None,
                    help="hardware profile name to plan against (registry "
                         "or calibrated store; default tpu_v5e)")
    ap.add_argument("--profile-store", default=".repro-profiles",
                    help="ProfileStore root searched before the registry "
                         "when --profile is a name")
    ap.add_argument("--json")
    args = ap.parse_args(argv)
    if args.all:
        res = grid(args.multi_pod, json_path=args.json,
                   counts_probes=not args.no_probes)
        sys.exit(0 if all(r.get("ok") for r in res) else 1)
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    rec = run_one(args.arch, args.shape, args.multi_pod,
                  counts_probes=not args.no_probes,
                  policy=args.policy, profile=args.profile,
                  profile_store=args.profile_store)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([rec], f, indent=1)


if __name__ == "__main__":
    main()
