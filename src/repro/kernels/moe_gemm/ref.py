"""Pure-jnp oracle for the grouped expert FFN (paper Eq. 3):
per expert e: y_e = silu(x_e @ Wg_e) * (x_e @ Wu_e) @ Wd_e."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gemm_ref(x, w_gate, w_up, w_down):
    """x: [E, C, M]; w_gate/w_up: [E, M, H]; w_down: [E, H, M] -> [E, C, M].
    Accumulation in float32, output in x.dtype."""
    xf = x.astype(jnp.float32)
    g = jnp.einsum("ecm,emh->ech", xf, w_gate.astype(jnp.float32))
    u = jnp.einsum("ecm,emh->ech", xf, w_up.astype(jnp.float32))
    y = jnp.einsum("ech,ehm->ecm", jax.nn.silu(g) * u,
                   w_down.astype(jnp.float32))
    return y.astype(x.dtype)
