"""jit'd public wrapper for the grouped expert FFN kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import on_tpu
from repro.kernels.moe_gemm.kernel import moe_gemm_pallas
from repro.kernels.moe_gemm.ref import moe_gemm_ref


@partial(jax.jit, static_argnames=("bc", "bh", "use_kernel"))
def moe_gemm(x, w_gate, w_up, w_down, bc: int = 128, bh: int = 512,
             use_kernel: bool = True):
    """Grouped expert SwiGLU FFN. Falls back to the jnp reference when the
    shapes do not tile (ragged C/H)."""
    E, C, M = x.shape
    H = w_gate.shape[-1]
    bc_ = min(bc, C)
    bh_ = min(bh, H)
    if not use_kernel or C % bc_ or H % bh_:
        return moe_gemm_ref(x, w_gate, w_up, w_down)
    return moe_gemm_pallas(x, w_gate, w_up, w_down, bc=bc_, bh=bh_,
                           interpret=not on_tpu())
