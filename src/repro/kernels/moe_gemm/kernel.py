"""Pallas TPU kernel: grouped per-expert SwiGLU FFN (the EG compute of DEP).

Tiling (TPU-native, see DESIGN.md hardware-adaptation):
  grid = (E, C // bc, H // bh)   — experts outermost, token tiles, then
                                   hidden tiles innermost so the f32
                                   accumulator for the down-projection
                                   lives in VMEM scratch across bh steps.
  Per step the MXU sees (bc x M) @ (M x bh) twice (gate, up) and
  (bc x bh) @ (bh x M) once (down) — all dims multiples of 128.

VMEM footprint per step (bc=128, bh=512, M<=4096, bf16):
  x tile 128xM (1MB) + Wg/Wu Mxbh (4MB) + Wd bhxM (4MB) + acc 128xM f32
  (2MB) -> ~11MB, under the 16MB/core budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *,
                n_h_steps: int):
    h_step = pl.program_id(2)

    @pl.when(h_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                     # [bc, M]
    g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
    act = (g * jax.lax.logistic(g)) * u              # silu(g) * u, f32
    acc_ref[...] += jnp.dot(act.astype(x.dtype), wd_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(h_step == n_h_steps - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gemm_pallas(x, w_gate, w_up, w_down, *, bc: int = 128,
                    bh: int = 512, interpret: bool = True):
    """x: [E, C, M]; w_gate/w_up: [E, M, H]; w_down: [E, H, M] -> [E, C, M]."""
    E, C, M = x.shape
    H = w_gate.shape[-1]
    bc = min(bc, C)
    bh = min(bh, H)
    assert C % bc == 0 and H % bh == 0, (C, bc, H, bh)
    n_c, n_h = C // bc, H // bh
    grid = (E, n_c, n_h)

    kernel = functools.partial(_ffn_kernel, n_h_steps=n_h)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, M), lambda e, c, h: (e, c, 0)),
            pl.BlockSpec((1, M, bh), lambda e, c, h: (e, 0, h)),
            pl.BlockSpec((1, M, bh), lambda e, c, h: (e, 0, h)),
            pl.BlockSpec((1, bh, M), lambda e, c, h: (e, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, M), lambda e, c, h: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, M), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, M), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
