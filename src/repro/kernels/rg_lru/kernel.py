"""Pallas TPU kernel: RG-LRU gated linear recurrence scan.

h_t = a_t * h_{t-1} + b_t over [B, S, W], elementwise in W — a pure
VPU workload. Grid = (B, W // bw, S // bs): the recurrence carry h lives in
VMEM scratch across the (innermost) sequence-chunk steps, so HBM traffic is
exactly one read of (a, b) and one write of h — the operational minimum —
instead of one state round-trip per timestep as in the naive scan.
Inside a chunk the loop over bs steps is a jax.lax.fori_loop on VMEM-
resident data (registers/VPU), which is what makes this kernel worth
having over lax.scan on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, hlast_ref, carry_ref, *,
                  bs: int, n_s_steps: int):
    s_step = pl.program_id(2)

    @pl.when(s_step == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)        # [bs, bw]
    b = b_ref[0].astype(jnp.float32)

    def body(t, carry):
        h = a[t] * carry + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, body, carry_ref[...])
    carry_ref[...] = h

    @pl.when(s_step == n_s_steps - 1)
    def _flush():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def rg_lru_scan_pallas(a, b, h0, *, bs: int = 256, bw: int = 512,
                       interpret: bool = True):
    """a, b: [B, S, W]; h0: [B, W] -> (h [B, S, W], h_last [B, W])."""
    B, S, W = a.shape
    bs = min(bs, S)
    bw = min(bw, W)
    assert S % bs == 0 and W % bw == 0, (S, bs, W, bw)
    n_s, n_w = S // bs, W // bw

    kernel = functools.partial(_rglru_kernel, bs=bs, n_s_steps=n_s)
    h, h_last = pl.pallas_call(
        kernel,
        grid=(B, n_w, n_s),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, si: (bi, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, si: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return h, h_last
