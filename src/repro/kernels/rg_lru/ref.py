"""Pure-jnp oracle for the RG-LRU linear recurrence
h_t = a_t * h_{t-1} + b_t   (gates precomputed), sequential scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rg_lru_scan_ref(a, b, h0):
    """a, b: [B, S, W] (f32); h0: [B, W] -> (h [B, S, W], h_last [B, W])."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    hs_last, hs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a.swapaxes(0, 1).astype(jnp.float32),
         b.swapaxes(0, 1).astype(jnp.float32)))
    return hs.swapaxes(0, 1), hs_last
