"""jit'd public wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import on_tpu
from repro.kernels.rg_lru.kernel import rg_lru_scan_pallas
from repro.kernels.rg_lru.ref import rg_lru_scan_ref


@partial(jax.jit, static_argnames=("bs", "bw", "use_kernel"))
def rg_lru_scan(a, b, h0, bs: int = 256, bw: int = 512,
                use_kernel: bool = True):
    B, S, W = a.shape
    bs_, bw_ = min(bs, S), min(bw, W)
    if not use_kernel or S % bs_ or W % bw_:
        return rg_lru_scan_ref(a, b, h0)
    return rg_lru_scan_pallas(a, b, h0, bs=bs_, bw=bw_,
                              interpret=not on_tpu())
