"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

Decode attention is HBM-bandwidth-bound: the whole cache is streamed once.
Grid = (B, Kv, C // bc); each step loads a [bc, D] K/V block into VMEM and
updates the flash state for the g query heads of that KV group in scratch.
The query block [g, D] stays resident. For g < 8 the MXU is underfed — the
kernel pads the q-group to 8 lanes (TPU sublane granularity); throughput is
cache-stream-bound anyway.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, n_c_steps: int, scale: float):
    c_step = pl.program_id(2)

    @pl.when(c_step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                  # [g, D]
    k = k_ref[0, :, 0]                               # [bc, D]
    v = v_ref[0, :, 0]
    valid = valid_ref[0]                             # [bc] int32 mask
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [g, bc]
    logits = jnp.where((valid > 0)[None, :], logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(-1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    m_ref[...] = m_new
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))

    @pl.when(c_step == n_c_steps - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, valid, *, bc: int = 512,
                            interpret: bool = True):
    """q: [B,H,D]; k/v_cache: [B,C,Kv,D]; valid: bool/int [C] -> [B,H,D]."""
    B, H, D = q.shape
    C, Kv = k_cache.shape[1], k_cache.shape[2]
    g = H // Kv
    bc = min(bc, C)
    assert C % bc == 0, (C, bc)
    n_c = C // bc

    qg = q.reshape(B, Kv, g, D)
    valid_i = jnp.broadcast_to(valid.astype(jnp.int32)[None], (B, C))

    kernel = functools.partial(_decode_kernel, n_c_steps=n_c,
                               scale=1.0 / math.sqrt(D))
    out = pl.pallas_call(
        kernel,
        grid=(B, Kv, n_c),
        in_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, kv, c: (b, kv, 0, 0)),
            pl.BlockSpec((1, bc, 1, D), lambda b, kv, c: (b, c, kv, 0)),
            pl.BlockSpec((1, bc, 1, D), lambda b, kv, c: (b, c, kv, 0)),
            pl.BlockSpec((1, bc), lambda b, kv, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), lambda b, kv, c: (b, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kv, g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, valid_i)
    return out.reshape(B, H, D)
