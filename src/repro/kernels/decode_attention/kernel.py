"""Pallas TPU kernel: single-token GQA decode attention over a ragged KV
cache.

Decode attention is HBM-bandwidth-bound: the cost of a step is the cache
bytes streamed. With continuous batching the cache is RAGGED — each slot
has its own context length — so streaming the full ``[B, max_context]``
cache wastes bandwidth proportional to (1 - occupancy). This kernel takes
per-row ``lengths [B]`` (the KV ledger) and

  * masks inside a block from ``lengths[b]`` (positions >= length get
    NEG_INF before the online softmax), and
  * skips KV blocks entirely past a row's length: the k/v index_map
    clamps the block index to the row's last in-range block, so the
    pipeline re-uses the already-resident block instead of issuing a new
    HBM stream, and ``pl.when`` skips the flash update. Streamed bytes
    scale with ceil(length/bc), not C/bc.

Grid = (B, Kv, C // bc); the flash state for the g query heads of one KV
group lives in VMEM scratch across the contraction steps. Rows with
length 0 (freshly-freed slots) execute no blocks and flush zeros.
``return_block_counts=True`` also returns the executed-block count per
(row, KV head) — the structural quantity CI verifies, since interpret
mode has no meaningful wall clock.

``paged_decode_attention_pallas`` is the block-table mode
(``repro.runtime.paging``): K/V live in physical page pools
``[P, bs, Kv, D]`` and a second scalar-prefetched operand — the per-row
block table — is dereferenced by the K/V ``index_map`` to turn a logical
block step into a physical page fetch. Same flash body, same skip
semantics; at ``bc == bs`` and identical logical contents the two modes
stream identical blocks in identical order, so outputs are bit-equal
(parity-locked in tests/test_paging.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def largest_block_size(C: int, bc: int) -> int:
    """Largest block size <= ``bc`` that divides ``C`` (the shape-crash
    fallback: C=600 with bc=512 used to assert; now it runs at bc=300)."""
    bc = max(min(bc, C), 1)
    while C % bc:
        bc -= 1
    return bc


def dense_kv_index_map(bc: int):
    """The dense-mode K/V ``index_map`` for block size ``bc``: clamp
    past-length steps to the row's last in-range block, so the pipeline
    sees an unchanged block index and skips the HBM fetch. Module-level
    (not a closure inside the pallas_call wrapper) so
    ``repro.analysis.kernelcheck`` can evaluate the exact production
    index math over the full grid with concrete integers."""
    def kv_map(b, kv, c, lens):
        last = jnp.maximum((lens[b] + bc - 1) // bc, 1) - 1
        return (b, jnp.minimum(c, last), kv, 0)
    return kv_map


def paged_kv_index_map(bs: int):
    """The block-table K/V ``index_map`` for page size ``bs``: clamp the
    logical block to the row's last in-range block, dereference the
    scalar-prefetched table, and clamp unallocated (-1) entries to the
    reserved scratch page 0. Module-level for
    ``repro.analysis.kernelcheck`` (see ``dense_kv_index_map``)."""
    def kv_map(b, kv, c, lens, tbl):
        last = jnp.maximum((lens[b] + bs - 1) // bs, 1) - 1
        page = tbl[b, jnp.minimum(c, last)]
        return (jnp.maximum(page, 0), 0, kv, 0)
    return kv_map


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, cnt_ref,
                   m_ref, l_ref, acc_ref, *, bc: int, n_c_steps: int,
                   scale: float):
    b = pl.program_id(0)
    c_step = pl.program_id(2)
    length = len_ref[b]
    start = c_step * bc

    @pl.when(c_step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[0, 0] = 0

    @pl.when(start < length)
    def _compute():
        q = q_ref[0, 0]                              # [g, D]
        k = k_ref[0, :, 0]                           # [bc, D]
        v = v_ref[0, :, 0]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, bc), 1)
        valid = pos < length                         # [1, bc]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [g, bc]
        logits = jnp.where(valid, logits, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(-1))
        p = jnp.exp(logits - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        cnt_ref[0, 0] += 1

    @pl.when(c_step == n_c_steps - 1)
    def _flush():
        # length-0 rows executed no block: acc == 0 flushes to exact zeros
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(len_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                         cnt_ref, m_ref, l_ref, acc_ref, *, bc: int,
                         n_c_steps: int, scale: float):
    # the block table is consumed by the K/V index_maps (it decides WHICH
    # physical page each grid step streams); the flash body is untouched
    del tbl_ref
    _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, cnt_ref,
                   m_ref, l_ref, acc_ref, bc=bc, n_c_steps=n_c_steps,
                   scale=scale)


def paged_decode_attention_pallas(q, k_pages, v_pages, lengths, block_table,
                                  *, interpret: bool = True,
                                  return_block_counts: bool = False):
    """Block-table mode: q: [B,H,D]; k/v_pages: [P,bs,Kv,D] physical page
    pools; ``block_table`` int [B,max_blocks] maps row b's logical block
    l to a physical page (< 0 = unallocated); lengths int [B] -> [B,H,D].

    Same flash loop as the dense kernel, but the K/V ``index_map``
    dereferences the scalar-prefetched block table, so a row's KV stream
    follows its page chain instead of a contiguous [B, C] row. Past-length
    steps clamp to the row's last in-range LOGICAL block — the table entry
    (hence the physical page index) is unchanged, the pipeline re-uses the
    resident page, and ``pl.when`` skips the update; unallocated entries
    clamp to page 0 (never dereferenced by an in-length step of a
    correctly-tabled row, and row 0 of the pool is a reserved scratch
    page on the serving path)."""
    B, H, D = q.shape
    bs, Kv = k_pages.shape[1], k_pages.shape[2]
    g = H // Kv
    n_blocks = block_table.shape[1]
    C = n_blocks * bs

    qg = q.reshape(B, Kv, g, D)
    lens = jnp.clip(jnp.asarray(lengths, jnp.int32), 0, C)
    tbl = jnp.asarray(block_table, jnp.int32)

    kv_map = paged_kv_index_map(bs)

    kernel = functools.partial(_paged_decode_kernel, bc=bs,
                               n_c_steps=n_blocks,
                               scale=1.0 / math.sqrt(D))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Kv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, g, D),
                         lambda b, kv, c, lens, tbl: (b, kv, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, bs, 1, D), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, D),
                         lambda b, kv, c, lens, tbl: (b, kv, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, kv, c, lens, tbl: (b, kv)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
    )
    out, counts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, Kv, g, D), q.dtype),
                   jax.ShapeDtypeStruct((B, Kv), jnp.int32)],
        interpret=interpret,
    )(lens, tbl, qg, k_pages, v_pages)
    out = out.reshape(B, H, D)
    if return_block_counts:
        return out, counts
    return out


def decode_attention_pallas(q, k_cache, v_cache, lengths, *, bc: int = 512,
                            interpret: bool = True,
                            return_block_counts: bool = False):
    """q: [B,H,D]; k/v_cache: [B,C,Kv,D]; lengths: int [B] -> [B,H,D].

    ``lengths[b]`` is the number of leading cache positions row b attends
    over (the KV ledger's context length); 0 yields a zero output row.
    ``bc`` is shrunk to the largest divisor of C when it does not tile.
    """
    B, H, D = q.shape
    C, Kv = k_cache.shape[1], k_cache.shape[2]
    g = H // Kv
    bc = largest_block_size(C, bc)
    n_c = C // bc

    qg = q.reshape(B, Kv, g, D)
    lens = jnp.clip(jnp.asarray(lengths, jnp.int32), 0, C)

    kv_map = dense_kv_index_map(bc)

    kernel = functools.partial(_decode_kernel, bc=bc, n_c_steps=n_c,
                               scale=1.0 / math.sqrt(D))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Kv, n_c),
        in_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, kv, c, lens: (b, kv, 0, 0)),
            pl.BlockSpec((1, bc, 1, D), kv_map),
            pl.BlockSpec((1, bc, 1, D), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, kv, c, lens: (b, kv, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, kv, c, lens: (b, kv)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
    )
    out, counts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, Kv, g, D), q.dtype),
                   jax.ShapeDtypeStruct((B, Kv), jnp.int32)],
        interpret=interpret,
    )(lens, qg, k_cache, v_cache)
    out = out.reshape(B, H, D)
    if return_block_counts:
        return out, counts
    return out
