"""jit'd public wrapper for the decode-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import on_tpu
from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


@partial(jax.jit, static_argnames=("bc", "use_kernel"))
def decode_attention(q, k_cache, v_cache, valid, bc: int = 512,
                     use_kernel: bool = True):
    C = k_cache.shape[1]
    bc_ = min(bc, C)
    if not use_kernel or C % bc_:
        return decode_attention_ref(q, k_cache, v_cache, valid)
    return decode_attention_pallas(q, k_cache, v_cache, valid, bc=bc_,
                                   interpret=not on_tpu())
