"""jit'd public wrapper for the ragged decode-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import on_tpu
from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas, largest_block_size,
    paged_decode_attention_pallas)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)


@partial(jax.jit, static_argnames=("bc", "use_kernel"))
def decode_attention(q, k_cache, v_cache, lengths, bc: int = 512,
                     use_kernel: bool = True):
    """q: [B,H,D]; k/v_cache: [B,C,Kv,D]; lengths: int [B] -> [B,H,D].

    Any cache length runs: a non-tiling ``bc`` falls back to the largest
    block size dividing C (C=600 at bc=512 runs at bc=300); only
    pathological lengths whose best divisor is tiny go to the oracle.
    """
    C = k_cache.shape[1]
    bc_ = largest_block_size(C, bc)
    if not use_kernel or (bc_ < 16 and C > 16):
        return decode_attention_ref(q, k_cache, v_cache, lengths)
    return decode_attention_pallas(q, k_cache, v_cache, lengths, bc=bc_,
                                   interpret=not on_tpu())


@partial(jax.jit, static_argnames=("use_kernel",))
def decode_attention_paged(q, k_pages, v_pages, lengths, block_table,
                           use_kernel: bool = True):
    """Paged-KV decode attention: q [B,H,D]; k/v_pages [P,bs,Kv,D];
    lengths int [B]; block_table int [B,max_blocks] -> [B,H,D].

    The kernel's block size IS the page size, so there is no divisor
    fallback — pools with pages too small to tile a TPU lane (< 16) take
    the gather-based oracle instead."""
    bs = k_pages.shape[1]
    if not use_kernel or bs < 16:
        return paged_decode_attention_ref(q, k_pages, v_pages, lengths,
                                          block_table)
    return paged_decode_attention_pallas(q, k_pages, v_pages, lengths,
                                         block_table,
                                         interpret=not on_tpu())
