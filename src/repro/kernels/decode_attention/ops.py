"""jit'd public wrapper for the ragged decode-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import on_tpu
from repro.kernels.decode_attention.kernel import (decode_attention_pallas,
                                                   largest_block_size)
from repro.kernels.decode_attention.ref import decode_attention_ref


@partial(jax.jit, static_argnames=("bc", "use_kernel"))
def decode_attention(q, k_cache, v_cache, lengths, bc: int = 512,
                     use_kernel: bool = True):
    """q: [B,H,D]; k/v_cache: [B,C,Kv,D]; lengths: int [B] -> [B,H,D].

    Any cache length runs: a non-tiling ``bc`` falls back to the largest
    block size dividing C (C=600 at bc=512 runs at bc=300); only
    pathological lengths whose best divisor is tiny go to the oracle.
    """
    C = k_cache.shape[1]
    bc_ = largest_block_size(C, bc)
    if not use_kernel or (bc_ < 16 and C > 16):
        return decode_attention_ref(q, k_cache, v_cache, lengths)
    return decode_attention_pallas(q, k_cache, v_cache, lengths, bc=bc_,
                                   interpret=not on_tpu())
