"""Pure-jnp oracle for single-token GQA decode attention over a ragged KV
cache: row b attends the first ``lengths[b]`` cache positions."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: [B,H,D]; k/v_cache: [B,C,Kv,D]; lengths: int [B] -> [B,H,D].

    Length-0 rows (freshly-freed slots) return exact zeros — a dense
    softmax over an all-masked row would return the mean of V instead.
    """
    B, H, D = q.shape
    C, Kv = k_cache.shape[1], k_cache.shape[2]
    g = H // Kv
    lengths = jnp.asarray(lengths, jnp.int32)
    qh = q.reshape(B, Kv, g, D).astype(jnp.float32)
    valid = jnp.arange(C)[None, :] < lengths[:, None]          # [B, C]
    logits = jnp.einsum("bkgd,bskd->bkgs", qh,
                        k_cache.astype(jnp.float32)) / math.sqrt(D)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    out = jnp.where((lengths > 0)[:, None, None, None], out, 0.0)
    return out.reshape(B, H, D).astype(q.dtype)
