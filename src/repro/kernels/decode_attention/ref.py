"""Pure-jnp oracle for single-token GQA decode attention over a ragged KV
cache: row b attends the first ``lengths[b]`` cache positions."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: [B,H,D]; k/v_cache: [B,C,Kv,D]; lengths: int [B] -> [B,H,D].

    Length-0 rows (freshly-freed slots) return exact zeros — a dense
    softmax over an all-masked row would return the mean of V instead.
    """
    B, H, D = q.shape
    C, Kv = k_cache.shape[1], k_cache.shape[2]
    g = H // Kv
    lengths = jnp.asarray(lengths, jnp.int32)
    qh = q.reshape(B, Kv, g, D).astype(jnp.float32)
    valid = jnp.arange(C)[None, :] < lengths[:, None]          # [B, C]
    logits = jnp.einsum("bkgd,bskd->bkgs", qh,
                        k_cache.astype(jnp.float32)) / math.sqrt(D)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    out = jnp.where((lengths > 0)[:, None, None, None], out, 0.0)
    return out.reshape(B, H, D).astype(q.dtype)


def gather_pages(pages, block_table):
    """[P,bs,Kv,D] pages + int [B,n] table -> dense [B, n*bs, Kv, D].
    Unallocated entries (< 0) clamp to page 0; their positions sit past
    the row's length, so the masked attention never reads them."""
    B, n = block_table.shape
    bs, Kv, D = pages.shape[1], pages.shape[2], pages.shape[3]
    tbl = jnp.maximum(jnp.asarray(block_table, jnp.int32), 0)
    return pages[tbl].reshape(B, n * bs, Kv, D)


def paged_decode_attention_ref(q, k_pages, v_pages, lengths, block_table):
    """Oracle for the paged kernel: gather each row's page chain into a
    dense cache, then run the dense reference."""
    return decode_attention_ref(q, gather_pages(k_pages, block_table),
                                gather_pages(v_pages, block_table), lengths)
