"""Pure-jnp oracle for single-token GQA decode attention over a KV cache."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def decode_attention_ref(q, k_cache, v_cache, valid):
    """q: [B,H,D]; k/v_cache: [B,C,Kv,D]; valid: bool [C] -> [B,H,D]."""
    B, H, D = q.shape
    Kv = k_cache.shape[2]
    g = H // Kv
    qh = q.reshape(B, Kv, g, D).astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qh,
                        k_cache.astype(jnp.float32)) / math.sqrt(D)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
