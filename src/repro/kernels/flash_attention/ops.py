"""jit'd public wrapper for the flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import on_tpu
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "use_kernel"))
def flash_attention(q, k, v, causal: bool = True, window=None,
                    bq: int = 128, bk: int = 128, use_kernel: bool = True):
    S = q.shape[1]
    bq_, bk_ = min(bq, S), min(bk, S)
    if not use_kernel or S % bq_ or S % bk_:
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq_, bk=bk_, interpret=not on_tpu())
