"""Pallas TPU kernel: causal GQA flash attention (prefill hot-spot).

Grid = (B * H, Sq // bq, Sk // bk), k-blocks innermost; the online-softmax
state (m, l) and the f32 output accumulator live in VMEM scratch across the
k-block steps. The KV block index map folds GQA: head h reads KV head
h // (H // Kv). Causal masking is positional inside the block; with
block-aligned shapes the MXU sees (bq x D) @ (D x bk) and (bq x bk) @
(bk x D), all dims multiples of 128 for bq = bk = 128, D = 128.

VMEM per step (bq=bk=128, D=128, bf16): q 32KB + k/v 64KB + acc f32 64KB +
m/l 1KB -> well under budget; larger bq/bk trade VMEM for fewer grid steps
(swept in benchmarks/kernel_blocks.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def flash_kv_index_map(H: int, Kv: int):
    """The K/V ``index_map`` over the flattened (B*H, n_q, n_k) grid:
    program bh covers batch bh // H, head bh % H, and folds GQA — head h
    reads KV row h // (H // Kv) of the flattened [B*Kv, S, D] operand.
    Module-level so ``repro.analysis.kernelcheck`` can evaluate it over
    the full grid with concrete integers."""
    g = H // Kv

    def kv_index(bh, qi, ki):
        return ((bh // H) * Kv + (bh % H) // g, ki, 0)
    return kv_index


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k_steps: int, bq: int, bk: int, causal: bool,
                  window, scale: float):
    q_step = pl.program_id(1)
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # [bq, D]
    k = k_ref[0]                                     # [bk, D]
    v = v_ref[0]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [bq, bk]

    q_pos = q_step * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = k_step * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(-1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    m_ref[...] = m_new
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))

    @pl.when(k_step == n_k_steps - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True):
    """q: [B,S,H,D]; k/v: [B,S,Kv,D] -> [B,S,H,D]."""
    B, S, H, D = q.shape
    Kv = k.shape[2]
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_q, n_k = S // bq, S // bk

    # flatten (B, H): program bh -> b = bh // H, h = bh % H, kv = h // g
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)

    kv_index = flash_kv_index_map(H, Kv)

    kernel = functools.partial(
        _flash_kernel, n_k_steps=n_k, bq=bq, bk=bk, causal=causal,
        window=window, scale=1.0 / math.sqrt(D))
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
