"""Pure-jnp oracle for causal (optionally windowed) GQA flash attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def flash_attention_ref(q, k, v, causal: bool = True, window=None):
    """q: [B,S,H,D]; k/v: [B,S,Kv,D] -> [B,S,H,D]."""
    B, S, H, D = q.shape
    Kv = k.shape[2]
    g = H // Kv
    qh = q.reshape(B, S, Kv, g, D).astype(jnp.float32)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qh,
                        k.astype(jnp.float32)) / math.sqrt(D)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= (pos[:, None] - pos[None, :]) < window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)
