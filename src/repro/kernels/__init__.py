"""Pallas TPU kernels for the compute hot-spots, each with a pure-jnp
oracle (ref.py) and a jit'd wrapper (ops.py). On CPU hosts the kernels run
in interpret mode (the kernel body executes in Python) — numerically
validated against the oracles in tests/test_kernels.py."""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"
