"""Pallas TPU kernel: mLSTM matrix-memory recurrence scan.

THE memory hot-spot of xLSTM on TPU: the naive lax.scan round-trips the
per-head state C in R^{DxD} through HBM every timestep —
2 * 4B * B*H*D^2 * S bytes (for xlstm-1.3b at 32k prefill that is ~100+
seconds of HBM time per device; see EXPERIMENTS.md §Perf pair C).

Here grid = (B, H, S // bs) with the (C, n, m) state resident in VMEM
scratch across the (innermost) sequence-chunk steps: HBM traffic is one
read of q/k/v/gates and one write of h — the operational minimum. For
D = 1024 the state is 4 MB f32, comfortably inside the 16 MB VMEM budget
with the [bs, D] streaming blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, cin_ref, nin_ref,
                  min_ref, h_ref, cout_ref, nout_ref, mout_ref,
                  C_ref, n_ref, m_ref, *, bs: int, n_s_steps: int):
    s_step = pl.program_id(2)

    @pl.when(s_step == 0)
    def _init():
        C_ref[...] = cin_ref[0, 0].astype(jnp.float32)
        n_ref[...] = nin_ref[0, 0].astype(jnp.float32)
        m_ref[...] = min_ref[0, 0].astype(jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32)      # [bs, D]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    ig = i_ref[0, 0].astype(jnp.float32)     # [bs]
    lf = f_ref[0, 0].astype(jnp.float32)

    def body(t, carry):
        C, n, m = carry
        m_new = jnp.maximum(lf[t] + m, ig[t])
        f_p = jnp.exp(lf[t] + m - m_new)
        i_p = jnp.exp(ig[t] - m_new)
        n_new = f_p * n + i_p * k[t]
        C_new = f_p * C + (i_p * v[t])[:, None] * k[t][None, :]
        denom = jnp.maximum(jnp.abs(jnp.sum(n_new * q[t])), 1.0)
        h_ref[0, 0, t, :] = (C_new @ q[t] / denom).astype(h_ref.dtype)
        return C_new, n_new, m_new

    C, n, m = jax.lax.fori_loop(
        0, bs, body, (C_ref[...], n_ref[...], m_ref[...]))
    C_ref[...] = C
    n_ref[...] = n
    m_ref[...] = m

    @pl.when(s_step == n_s_steps - 1)
    def _flush():
        cout_ref[0, 0] = C.astype(cout_ref.dtype)
        nout_ref[0, 0] = n.astype(nout_ref.dtype)
        mout_ref[0, 0] = m.astype(mout_ref.dtype)


def mlstm_scan_pallas(q, k, v, i_gate, log_f, C0, n0, m0, *, bs: int = 128,
                      interpret: bool = True):
    """q,k,v: [B,H,S,D]; i_gate/log_f: [B,H,S]; C0: [B,H,D,D];
    n0: [B,H,D]; m0: [B,H]. Returns (h [B,H,S,D], C, n, m)."""
    B, H, S, D = q.shape
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    n_s = S // bs
    m0e = m0[..., None]                       # [B,H,1] (2D-min blocks)

    kernel = functools.partial(_mlstm_kernel, bs=bs, n_s_steps=n_s)
    h, C, n, m = pl.pallas_call(
        kernel,
        grid=(B, H, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs), lambda b, h, s: (b, h, s)),
            pl.BlockSpec((1, 1, bs), lambda b, h, s: (b, h, s)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, s: (b, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, s: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((D, D), jnp.float32),
            pltpu.VMEM((D,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, i_gate, log_f, C0, n0, m0e)
    return h, C, n, m[..., 0]
