"""jit'd public wrapper for the mLSTM recurrence kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import on_tpu
from repro.kernels.mlstm_scan.kernel import mlstm_scan_pallas
from repro.kernels.mlstm_scan.ref import mlstm_scan_ref


@partial(jax.jit, static_argnames=("bs", "use_kernel"))
def mlstm_scan(q, k, v, i_gate, log_f, C0, n0, m0, bs: int = 128,
               use_kernel: bool = True):
    S = q.shape[2]
    bs_ = min(bs, S)
    if not use_kernel or S % bs_:
        return mlstm_scan_ref(q, k, v, i_gate, log_f, C0, n0, m0)
    return mlstm_scan_pallas(q, k, v, i_gate, log_f, C0, n0, m0, bs=bs_,
                             interpret=not on_tpu())
