"""Pure-jnp oracle for the stabilized mLSTM matrix-memory recurrence
(xLSTM, arXiv:2405.04517):

    m_t = max(lf_t + m_{t-1}, i_t)
    f'  = exp(lf_t + m_{t-1} - m_t);  i' = exp(i_t - m_t)
    C_t = f' C_{t-1} + i' v_t k_t^T
    n_t = f' n_{t-1} + i' k_t
    h_t = C_t q_t / max(|n_t . q_t|, 1)

q,k,v: [B,H,S,D] (k pre-scaled by 1/sqrt(D)); i,lf: [B,H,S] (lf = log
sigmoid of the raw forget gate). Returns (h [B,H,S,D], C, n, m finals).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_scan_ref(q, k, v, i_gate, log_f, C0, n0, m0):
    B, H, S, D = q.shape

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, lft = xs
        m_new = jnp.maximum(lft + m, it)
        f_p = jnp.exp(lft + m - m_new)[..., None]
        i_p = jnp.exp(it - m_new)[..., None]
        n_new = f_p * n + i_p * kt
        C_new = f_p[..., None] * C + (i_p * vt)[..., None] * kt[..., None, :]
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, qt)), 1.0)
        h = jnp.einsum("bhvd,bhd->bhv", C_new, qt) / denom[..., None]
        return (C_new, n_new, m_new), h

    xs = (q.swapaxes(0, 2).swapaxes(1, 2),   # [S,B,H,D]
          k.swapaxes(0, 2).swapaxes(1, 2),
          v.swapaxes(0, 2).swapaxes(1, 2),
          i_gate.transpose(2, 0, 1), log_f.transpose(2, 0, 1))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 2, 0, 3), C, n, m
