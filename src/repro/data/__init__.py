from repro.data.pipeline import DataConfig, MarkovTextDataset

__all__ = ["DataConfig", "MarkovTextDataset"]
