"""Synthetic-but-learnable token pipeline.

Markov-chain token streams: a fixed random transition table over the vocab
gives next-token structure a model can actually learn (loss decreases),
unlike uniform noise. Deterministic per seed, sharded per host, infinite.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    branching: int = 8        # successors per token (lower => easier)


class MarkovTextDataset:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        V = cfg.vocab_size
        self.successors = rng.randint(0, V, size=(V, cfg.branching))
        self.probs = rng.dirichlet(np.ones(cfg.branching), size=V)

    def sample_batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed + 1) * 100003 + step)
        B, S = cfg.batch_size, cfg.seq_len
        out = np.empty((B, S), np.int32)
        out[:, 0] = rng.randint(0, cfg.vocab_size, size=B)
        for t in range(1, S):
            cur = out[:, t - 1]
            choice = np.array([rng.choice(cfg.branching, p=self.probs[c])
                               for c in cur])
            out[:, t] = self.successors[cur, choice]
        return out

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.sample_batch(step)
            step += 1

    def optimal_nll(self) -> float:
        """Entropy of the transition distribution = the loss floor."""
        p = self.probs
        ent = -(p * np.log(np.maximum(p, 1e-12))).sum(-1)
        return float(ent.mean())
